"""Serving benchmark → ``BENCH_serve.json``.

Continuous vs static admission on the SAME Poisson arrival schedule: a
bimodal request mix (short prompts that want many tokens, long prompts
that want few — the shape that makes drain-then-refill hurt) arrives
keyed on the engine-step index, and each mode runs the identical
schedule through one ServeEngine.  Static admission refills the decode
slab only when it is fully drained, so every batch runs at the pace of
its longest member while finished slots idle; continuous admission
refills slots the moment they free.

Reported per mode: request latency p50/p99 (ms), throughput (generated
tok/s over the makespan), makespan (s), and the engine's exact wave
counters (decode waves are deterministic — the wall-clock numbers track
them).

Gate (CI): continuous strictly beats static on makespan AND decode-wave
count for the bimodal mix — continuous batching must actually buy
something, not just exist.

Run: ``python -m benchmarks.serve_bench [--out PATH]``
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

SNAPSHOT_PATH = "BENCH_serve.json"

ARCH = "llama3.2-3b"
MAX_SLOTS = 4
MAX_CONTEXT = 96
CAPACITY = 96
N_REQS = 12
ARRIVAL_RATE = 1.5      # mean engine-steps between arrivals (Poisson)


def _schedule(seed: int = 0):
    """The shared arrival schedule: (arrival_step, prompt, max_new).
    Bimodal — short prompts decode long, long prompts decode short."""
    rng = np.random.RandomState(seed)
    gaps = rng.poisson(ARRIVAL_RATE, N_REQS)
    steps = np.cumsum(gaps)
    out = []
    for i in range(N_REQS):
        if i % 2 == 0:
            plen, mnt = int(rng.randint(4, 12)), 28
        else:
            plen, mnt = int(rng.randint(32, 56)), 4
        prompt = rng.randint(0, 1000, plen)
        out.append((int(steps[i]), prompt, mnt))
    return out


def _build(admission: str):
    import jax

    from repro import compat
    from repro.configs.registry import get_config
    from repro.models.transformer import init_params
    from repro.parallel.sharding import single_device_runtime
    from repro.serve import ServeConfig, ServeEngine

    cfg = get_config(ARCH).reduced()
    rt = single_device_runtime(remat="none")
    compat.set_mesh(rt.mesh)
    params = init_params(jax.random.PRNGKey(0), cfg, rt)
    scfg = ServeConfig(max_slots=MAX_SLOTS, max_context=MAX_CONTEXT,
                       prefill_capacity=CAPACITY, admission=admission)
    return ServeEngine(params, cfg, rt, scfg)


def run_case(admission: str) -> dict:
    """One mode over the shared schedule.  A warmup pool (one request of
    each class, drained before the clock starts) pays the decode compile
    and the common prefill compositions so the measurement compares
    admission policies, not jit caches."""
    eng = _build(admission)
    warm_rng = np.random.RandomState(99)
    for plen, mnt in ((8, 2), (48, 2)):
        eng.submit(warm_rng.randint(0, 1000, plen), mnt)
    eng.drain(max_steps=200)
    # pre-compile every shape the schedule will touch: the prefill→decode
    # cache scatters are eager ops keyed on (plen, window) shapes, so an
    # unwarmed plen pays its XLA compile inside the measurement — and
    # WHICH mode pays depends on run order (the eager compile cache is
    # process-global).  max_new_tokens=1 retires at prefill, so warmup
    # never occupies decode slots
    sched = _schedule()
    for _, prompt, _ in sched:
        eng.submit(prompt, 1)
    eng.drain(max_steps=200)
    eng.records.clear()
    waves0 = dict(eng.stats)
    rids, pending = [], list(sched)
    t0 = time.perf_counter()
    step = 0
    while pending or eng.pool.n_open:
        while pending and pending[0][0] <= step:
            _, prompt, mnt = pending.pop(0)
            rids.append(eng.submit(prompt, mnt))
        eng.step()
        step += 1
        if step > 10_000:
            raise RuntimeError("serve bench did not converge")
    wall = time.perf_counter() - t0

    recs = {r["rid"]: r for r in eng.records}
    lat = np.array([recs[r]["t_done"] - recs[r]["t_submit"] for r in rids])
    toks = sum(recs[r]["n_tokens"] for r in rids)
    makespan = (max(recs[r]["t_done"] for r in rids)
                - min(recs[r]["t_submit"] for r in rids))
    return {
        "n_reqs": len(rids),
        "tokens": int(toks),
        "makespan_s": round(float(makespan), 4),
        "wall_s": round(float(wall), 4),
        "latency_p50_ms": round(float(np.percentile(lat, 50)) * 1e3, 2),
        "latency_p99_ms": round(float(np.percentile(lat, 99)) * 1e3, 2),
        "tok_per_s": round(toks / max(makespan, 1e-9), 2),
        "decode_waves": eng.stats["decode_waves"] - waves0["decode_waves"],
        "prefill_waves": (eng.stats["prefill_waves"]
                          - waves0["prefill_waves"]),
        "compiled_compositions": eng.stats["compiled_compositions"],
    }


def snapshot(path: str = SNAPSHOT_PATH, cases: dict = None) -> dict:
    cases = cases or {m: run_case(m) for m in ("continuous", "static")}
    cont, stat = cases["continuous"], cases["static"]
    snap = {
        "mix": {"arch": ARCH, "n_reqs": N_REQS, "max_slots": MAX_SLOTS,
                "arrival_rate": ARRIVAL_RATE},
        "continuous": cont, "static": stat,
        "makespan_reduction": round(
            1.0 - cont["makespan_s"] / max(stat["makespan_s"], 1e-9), 4),
        "decode_wave_reduction": stat["decode_waves"] - cont["decode_waves"],
        "gate_ok": bool(cont["makespan_s"] < stat["makespan_s"]
                        and cont["tok_per_s"] > stat["tok_per_s"]
                        and cont["latency_p99_ms"] < stat["latency_p99_ms"]
                        and cont["decode_waves"] < stat["decode_waves"]),
    }
    with open(path, "w") as f:
        json.dump(snap, f, indent=1, sort_keys=True)
        f.write("\n")
    return snap


def rows_from(snap: dict) -> list:
    rows = []
    for mode in ("continuous", "static"):
        c = snap[mode]
        rows.append((f"serve.{mode}", c["makespan_s"] * 1e6,
                     f"p99={c['latency_p99_ms']}ms "
                     f"tok/s={c['tok_per_s']} waves={c['decode_waves']}"))
    rows.append(("serve.makespan_reduction",
                 0.0, f"{snap['makespan_reduction']:.1%}"))
    return rows


def run() -> list:
    """benchmarks/run.py entry: snapshot + CSV rows."""
    return rows_from(snapshot())


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=SNAPSHOT_PATH)
    args = ap.parse_args()
    snap = snapshot(path=args.out)
    for name, us, derived in rows_from(snap):
        print(f"{name},{us:.1f},{derived}")
    if not snap["gate_ok"]:
        raise SystemExit(
            f"serve gate FAILED: continuous (makespan "
            f"{snap['continuous']['makespan_s']}s, "
            f"{snap['continuous']['decode_waves']} decode waves) must beat "
            f"static ({snap['static']['makespan_s']}s, "
            f"{snap['static']['decode_waves']} waves)")


if __name__ == "__main__":
    main()
