"""Fig. 21: offload ratio vs memory saved / overlap feasibility per
context length (paper: r=0.5 free at 64K; r=1.0 free at 256K)."""
import time

from repro.configs.registry import get_config
from repro.core import offload as OF


def run():
    cfg = get_config("llama-7b")
    hw = OF.OffloadHW(d2h_bw=10e9, h2d_bw=10e9, peak_flops=300e12)
    base = OF.analytic_coeffs(cfg, hw)
    # the paper offloads the FULL activation set, not remat residuals
    full_act = (10 * cfg.d_model + 3 * cfg.d_ff) * 2
    coeffs = OF.CostCoeffs(a1=base.a1, b1=base.b1, g=base.g,
                           a2=float(full_act), b2=0.0)
    rows = []
    for s in (65_536, 262_144, 1_048_576):
        t0 = time.perf_counter()
        r_max = OF.max_overlap_ratio(coeffs, s, hw)
        r, d = OF.solve_eq3(coeffs, s, 8192, cfg.num_layers, hw)
        d2h, h2d = OF.eq3_bytes(coeffs, s, r, cfg.num_layers, hw)
        us = (time.perf_counter() - t0) * 1e6
        mem_saved = r * (cfg.num_layers - 2) / cfg.num_layers
        rows.append((f"fig21.ctx{s//1024}K", us,
                     f"free_ratio={min(r_max,1.0):.2f} eq3_r={r:.2f} "
                     f"D={d} mem_saved_frac={mem_saved:.2f} "
                     f"d2h_gb={d2h/1e9:.1f} h2d_gb={h2d/1e9:.1f}"))
    return rows
