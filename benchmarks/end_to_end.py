"""Fig. 17: end-to-end throughput, {static, naive-HDP, balanced-HDP} ×
models × context lengths × datasets.  Simulated with the Balance
Scheduler's own cost model under (a) paper-like A100/IB constants — the
validation against the paper's claims — and (b) TPU v5e constants — this
system's expectation (EXPERIMENTS.md discusses the gap)."""
import time

from benchmarks.common import PAPER_HW, TPU_HW, simulate

CASES = [
    ("llama-7b", "github", 2_097_152, 256),
    ("llama-7b", "byted", 2_097_152, 256),
    ("llama-7b", "github", 262_144, 256),
    ("llama-13b", "github", 1_048_576, 256),
    ("llama-70b", "github", 2_097_152, 128),
    ("mistral-8x7b", "github", 1_048_576, 128),
]


def run():
    rows = []
    for hw_name, hwset in (("paperhw", PAPER_HW), ("tpuv5e", TPU_HW)):
        for model, ds, ctx, hdp in CASES:
            t0 = time.perf_counter()
            _, plans = simulate(model, ds, ctx, hdp=hdp, hwset=hwset,
                                tokens=16_000_000)
            us = (time.perf_counter() - t0) * 1e6
            st = plans["static"].stats["makespan"]
            nv = plans["naive"].stats["makespan"]
            bl = plans["balance"].stats["makespan"]
            derived = (f"static_tok/s={4e6/st:.0f}"
                       f" naive_x={st/nv:.2f} balance_x={st/bl:.2f}")
            rows.append((f"fig17.{hw_name}.{model}.{ds}.{ctx//1024}K",
                         us, derived))
    return rows
