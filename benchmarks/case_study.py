"""Fig. 18: per-rank execution profile, LLaMA-7B @ 2M context on the Byted
mix (paper: naive shows a 4.7× max/min spread; balance flattens it)."""
import time

import numpy as np

from benchmarks.common import PAPER_HW, simulate


def run():
    t0 = time.perf_counter()
    _, plans = simulate("llama-7b", "byted", 2_097_152, hdp=256,
                        hwset=PAPER_HW, tokens=16_000_000,
                        strategies=("static", "naive", "balance"))
    us = (time.perf_counter() - t0) * 1e6
    rows = []
    for name, plan in plans.items():
        per_rank = np.asarray(plan.stats["per_rank_times"])
        nz = per_rank[per_rank > 0]
        derived = (f"max={per_rank.max():.0f}s min={nz.min():.0f}s "
                   f"std={per_rank.std():.0f}s "
                   f"maxmin_ratio={per_rank.max()/max(nz.min(),1e-9):.1f}")
        rows.append((f"fig18.{name}.per_rank", us / 3, derived))
    return rows
