"""Pipeline-parallel training demo: PP-Balance end-to-end on 8 CPU devices.

A 2-stage x 2-HDP x 2-TP mesh trains a small dense model with the
pipelined executor: the scheduler plans in PP-Balance mode (every wave
one composition -> one pipelined round per step), each wave runs as a
pipeline microbatch through the wavefront schedule, and the per-step
record reports both the planner's bubble and the pipelined executor's
lockstep bubble.

    PYTHONPATH=src python examples/train_pp.py --steps 5
"""
import os
# 8 host-platform devices BEFORE any jax import (jax locks the device
# count on first init); honours an externally-set XLA_FLAGS (e.g. CI)
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import argparse

from repro import compat
from repro.configs.base import ModelConfig
from repro.data.distribution import LengthDistribution
from repro.data.loader import GlobalScheduler, SyntheticDataset
from repro.launch.mesh import hdp_axes_of, make_pipeline_mesh
from repro.optim.adamw import AdamWConfig
from repro.parallel.sharding import Runtime
from repro.train.trainer import Trainer, TrainerConfig

CFG = ModelConfig(
    name="demo-pp", family="dense", num_layers=4, d_model=256,
    num_heads=8, num_kv_heads=4, head_dim=32, d_ff=1024, vocab_size=8192,
    layer_pattern="g", pos_embed="rope", act="silu", gated_mlp=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=5)
    ap.add_argument("--num-stages", type=int, default=2)
    ap.add_argument("--hdp", type=int, default=2)
    ap.add_argument("--tp", type=int, default=2)
    ap.add_argument("--capacity", type=int, default=512)
    args = ap.parse_args()

    mesh = make_pipeline_mesh(args.num_stages, args.hdp, args.tp)
    compat.set_mesh(mesh)
    rt = Runtime(mesh=mesh, hdp_axes=hdp_axes_of(mesh), model_axis="model",
                 stage_axis="stage", remat="none", kv_chunk=128)
    print(f"mesh stage x data x model = {args.num_stages} x {args.hdp} "
          f"x {args.tp}  ({mesh.devices.size} devices)")

    dist = LengthDistribution("demo", 4.5, 0.9, 0.1, 1.5, 1024)
    ds = SyntheticDataset(dist, CFG.vocab_size, tokens_per_step=4096,
                          context=2048)
    sched = GlobalScheduler(ds, CFG, capacity=args.capacity, hdp=args.hdp,
                            mode="pp", strategy="balance", use_offload=False,
                            num_stages=args.num_stages)
    trainer = Trainer(CFG, rt,
                      AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=100),
                      sched, TrainerConfig(capacity=args.capacity,
                                           mode="pp"))
    for rec in trainer.run(args.steps):
        print(f"step {rec['step']:3d}  loss {rec['loss']:.4f}  "
              f"waves {rec['waves']}  rounds {rec['rounds']}  "
              f"pipeline-bubble {rec['bubble_frac_pipeline']:.1%}  "
              f"{rec['wall_s']:.1f}s")


if __name__ == "__main__":
    main()
