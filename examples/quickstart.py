"""Quickstart: build a reduced model, plan an HDP step, train a few waves.

    PYTHONPATH=src python examples/quickstart.py [--arch llama3.2-3b]
"""
import argparse

from repro import compat
from repro.configs.registry import get_config
from repro.data.distribution import LengthDistribution
from repro.data.loader import GlobalScheduler, SyntheticDataset
from repro.optim.adamw import AdamWConfig
from repro.parallel.sharding import single_device_runtime
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--steps", type=int, default=5)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    rt = single_device_runtime(remat="none")
    compat.set_mesh(rt.mesh)
    print(f"arch={cfg.name}  d_model={cfg.d_model}  layers={cfg.num_layers}  "
          f"pattern={cfg.layer_pattern}")

    dist = LengthDistribution("demo", 4.5, 0.9, 0.1, 1.5, 1024)
    ds = SyntheticDataset(dist, cfg.vocab_size, tokens_per_step=8192,
                          context=2048)
    sched = GlobalScheduler(ds, cfg, capacity=512, hdp=1,
                            strategy="balance", use_offload=False)
    trainer = Trainer(cfg, rt, AdamWConfig(lr=1e-3, warmup_steps=2,
                                           total_steps=100),
                      sched, TrainerConfig(capacity=512))
    for rec in trainer.run(args.steps):
        print(f"step {rec['step']:3d}  loss {rec['loss']:.4f}  "
              f"waves {rec['waves']}  plan-bubble {rec['bubble_frac']:.1%}  "
              f"{rec['wall_s']:.1f}s")


if __name__ == "__main__":
    main()
