"""Lookahead scheduling service demo: cross-step balance + compile reuse.

Part 1 (plan level, no devices needed): plan a K-step window of a bimodal
length mix per-step and through the lookahead window planner, and print
the window makespan / distinct-executable comparison.

Part 2 (execution): run a few training steps with the scheduler service's
async planner thread on — plans and wave buffers for step t+1 are built
while step t executes — and show the compile cache staying small.

    PYTHONPATH=src python examples/lookahead_demo.py --steps 6
"""
import argparse

import numpy as np

from repro import compat
from repro.configs.base import ModelConfig
from repro.configs.registry import get_config
from repro.core.planner import PlanSpec, plan, plan_window
from repro.data.distribution import LengthDistribution
from repro.data.loader import GlobalScheduler, SyntheticDataset
from repro.optim.adamw import AdamWConfig
from repro.parallel.sharding import single_device_runtime
from repro.sched.lookahead import window_stats
from repro.train.trainer import Trainer, TrainerConfig

CFG_TINY = ModelConfig(
    name="demo-tiny", family="dense", num_layers=4, d_model=256,
    num_heads=4, num_kv_heads=2, head_dim=64, d_ff=1024, vocab_size=8_000,
    layer_pattern="g", pos_embed="rope", act="silu", gated_mlp=True)


def plan_level_demo(k: int = 4, hdp: int = 8):
    cfg = get_config("llama-7b")
    capacity = 8192
    spec = PlanSpec.for_config(cfg, capacity=capacity, hdp=hdp,
                               use_offload=False)
    window = []
    for t in range(k):
        rng = np.random.default_rng(1000 + t)
        longs = [int(x) * capacity for x in rng.integers(2, 6, 3)]
        shorts = [int(x) for x in np.clip(rng.lognormal(6.8, 0.6, 400),
                                          256, capacity // 2)]
        window.append(longs + shorts)
    per_step = [plan(l, spec) for l in window]
    look = plan_window(window, spec)
    ps, lk = window_stats(per_step), window_stats(look)
    print(f"window of {k} steps, hdp={hdp} (bimodal mix)")
    print(f"  per-step : makespan {ps['window_makespan']:.2f}  "
          f"distinct executables {ps['distinct_keys']}")
    print(f"  lookahead: makespan {lk['window_makespan']:.2f}  "
          f"distinct executables {lk['distinct_keys']}  "
          f"(ideal {lk['ideal']:.2f})")


def async_training_demo(steps: int):
    rt = single_device_runtime(remat="none")
    compat.set_mesh(rt.mesh)
    dist = LengthDistribution("mix", 5.0, 1.0, 0.05, 1.3, 1024)
    ds = SyntheticDataset(dist, CFG_TINY.vocab_size, tokens_per_step=4096,
                          context=2048)
    sched = GlobalScheduler(ds, CFG_TINY, capacity=512, hdp=rt.hdp_size,
                            use_offload=False, lookahead=2,
                            sched_async=True)
    trainer = Trainer(
        CFG_TINY, rt, AdamWConfig(lr=3e-4, total_steps=steps), sched,
        TrainerConfig(capacity=512, sched_async=True))
    print(f"\nasync training ({steps} steps, lookahead=2):")
    for rec in trainer.run(steps):
        print(f"  step {rec['step']:3d}  loss {rec['loss']:.4f}  "
              f"waves {rec['waves']}  "
              f"executables {len(trainer._exec_cache)}  "
              f"wall {rec['wall_s']:.2f}s")
    sched.stop()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=6)
    args = ap.parse_args()
    plan_level_demo()
    async_training_demo(args.steps)


if __name__ == "__main__":
    main()
