"""End-to-end driver: train a ~100M-param dense model for a few hundred
steps on a skewed synthetic mix with the full HDP pipeline (balance
scheduler + waves + checkpoints).

    PYTHONPATH=src python examples/train_hdp.py --steps 200
"""
import argparse
import dataclasses as dc

from repro import compat
from repro.configs.base import ModelConfig
from repro.data.distribution import LengthDistribution
from repro.data.loader import GlobalScheduler, SyntheticDataset
from repro.optim.adamw import AdamWConfig
from repro.parallel.sharding import single_device_runtime
from repro.train.trainer import Trainer, TrainerConfig

# ~100M params: 8L, d=512, ffn 2048, vocab 32k
CFG_100M = ModelConfig(
    name="demo-100m", family="dense", num_layers=8, d_model=512,
    num_heads=8, num_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=32_000,
    layer_pattern="g", pos_embed="rope", act="silu", gated_mlp=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--tokens-per-step", type=int, default=16_384)
    ap.add_argument("--capacity", type=int, default=2048)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_hdp_ckpt")
    args = ap.parse_args()

    rt = single_device_runtime(remat="none")
    compat.set_mesh(rt.mesh)
    dist = LengthDistribution("mix", 5.5, 1.0, 0.05, 1.3, 2048)
    ds = SyntheticDataset(dist, CFG_100M.vocab_size, args.tokens_per_step,
                          context=8192)
    sched = GlobalScheduler(ds, CFG_100M, capacity=args.capacity, hdp=2,
                            strategy="balance", use_offload=False)
    trainer = Trainer(
        CFG_100M, rt,
        AdamWConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps),
        sched, TrainerConfig(capacity=args.capacity, ckpt_every=50,
                             ckpt_dir=args.ckpt_dir))
    if trainer.resume_if_possible():
        print(f"resumed from step {trainer.step}")
    for rec in trainer.run(args.steps - trainer.step):
        if rec["step"] % 10 == 0 or rec["step"] <= 3:
            print(f"step {rec['step']:4d}  loss {rec['loss']:.4f}  "
                  f"waves {rec['waves']}  gnorm {rec['grad_norm']:.2f}")


if __name__ == "__main__":
    main()
