"""Visualize what the paper is about: plan a skewed 2M-context global batch
three ways and print the per-rank timeline statistics (Fig. 13/18).

    PYTHONPATH=src python examples/balance_demo.py
"""
import numpy as np

from repro.configs.registry import get_config
from repro.core import offload as OF
from repro.core.planner import PlanSpec, plan as plan_batch
from repro.data.distribution import DISTRIBUTIONS


def bar(frac, width=40):
    return "#" * int(frac * width)


def main():
    cfg = get_config("llama-7b")
    hw = OF.OffloadHW(d2h_bw=12e9, h2d_bw=12e9, peak_flops=300e12)
    base = PlanSpec.for_config(cfg, capacity=8192, hdp=64, hw=hw,
                               ici_bw=25e9)
    rng = np.random.default_rng(7)
    lens = DISTRIBUTIONS["byted"].sample_tokens(rng, 8_000_000, 2_097_152)
    print(f"global batch: {len(lens)} sequences, {sum(lens)/1e6:.1f}M tokens,"
          f" max {max(lens)/1024:.0f}K")
    plans = {
        "static-CP": plan_batch(lens, base.replace(strategy="static",
                                                   cp_degree=64)),
        "naive-HDP": plan_batch(lens, base.replace(strategy="naive",
                                                   use_offload=False)),
        "balanced-HDP": plan_batch(lens, base.replace(strategy="balance",
                                                      mode="dp")),
    }
    base = plans["static-CP"].stats["makespan"]
    for name, plan in plans.items():
        s = plan.stats
        per_rank = np.asarray(s["per_rank_times"])
        print(f"\n== {name}:  makespan {s['makespan']:.0f}s "
              f"(speedup {base/s['makespan']:.2f}x), "
              f"{s['n_waves']} waves, bubble {s['bubble_frac']:.1%}")
        for r in range(0, len(per_rank), len(per_rank) // 8):
            print(f"  rank {r:3d} |{bar(per_rank[r]/per_rank.max()):40s}| "
                  f"{per_rank[r]:.0f}s")


if __name__ == "__main__":
    main()
