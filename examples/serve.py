"""Batched decoding demo: prefill-free autoregressive generation with the
sharded-cache decode path (flash-decoding combine on real hardware).

    PYTHONPATH=src python examples/serve.py --arch gemma2-9b --tokens 32
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.configs.registry import get_config
from repro.models.transformer import init_params
from repro.parallel.sharding import single_device_runtime
from repro.train.serve_step import init_decode_cache, make_decode_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-9b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    rt = single_device_runtime(remat="none")
    compat.set_mesh(rt.mesh)
    params = init_params(jax.random.PRNGKey(0), cfg, rt)
    b, horizon = args.batch, args.tokens
    cache = init_decode_cache(cfg, rt, b, horizon)
    step = jax.jit(make_decode_step(cfg, rt, b, horizon),
                   static_argnames=())

    rng = np.random.RandomState(0)
    tok = jnp.array(rng.randint(0, cfg.vocab_size, b))
    outs = []
    for i in range(horizon):
        logits, cache = step(params, cache, tok, jnp.int32(i))
        tok = jnp.argmax(logits, axis=-1)
        outs.append(np.asarray(tok))
    gen = np.stack(outs, 1)
    print(f"{cfg.name}: generated {gen.shape} token grid")
    for row in gen[:2]:
        print("  ", row[:16], "...")


if __name__ == "__main__":
    main()
