"""Serving demo: continuous batching on the HDP planner.

A ServeEngine takes a stream of mixed-length prompts, plans prefill
waves with the same `core.planner.plan` the trainer uses (long prompts
CP-sharded, short ones packed), hands the prefill KV into a fixed decode
slab, and decodes every live request one token per wave — admitting new
arrivals into slots the moment they free.

    PYTHONPATH=src python examples/serve.py --arch llama3.2-3b --reqs 6

For the multi-process shape (controller as request router, workers as
engines) see `repro.ctrl.controller.Controller.run_serve` and
`repro.serve.router.ServeClient`.
"""
import argparse

import jax
import numpy as np

from repro import compat
from repro.configs.registry import get_config
from repro.models.transformer import init_params
from repro.parallel.sharding import single_device_runtime
from repro.serve import ServeConfig, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--reqs", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--context", type=int, default=96)
    ap.add_argument("--tokens", type=int, default=12)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    rt = single_device_runtime(remat="none")
    compat.set_mesh(rt.mesh)
    params = init_params(jax.random.PRNGKey(0), cfg, rt)
    eng = ServeEngine(params, cfg, rt,
                      ServeConfig(max_slots=args.slots,
                                  max_context=args.context,
                                  prefill_capacity=args.context))

    rng = np.random.RandomState(0)
    rids = []
    for i in range(args.reqs):
        plen = int(rng.randint(4, args.context - args.tokens))
        rids.append(eng.submit(rng.randint(0, cfg.vocab_size, plen),
                               args.tokens))
    eng.drain(max_steps=10_000)

    print(f"{cfg.name}: served {len(rids)} requests "
          f"({eng.stats['prefill_waves']} prefill waves, "
          f"{eng.stats['decode_waves']} decode waves, "
          f"{eng.stats['compiled_compositions']} compositions compiled)")
    for rid in rids:
        r = eng.pool.get(rid)
        ttft = (r.t_first - r.t_submit) * 1e3
        e2e = (r.t_done - r.t_submit) * 1e3
        print(f"  req {rid}: plen={r.plen:3d} -> {len(r.generated):3d} tok  "
              f"ttft={ttft:7.1f}ms  e2e={e2e:8.1f}ms  "
              f"tokens[:8]={r.generated[:8]}")


if __name__ == "__main__":
    main()
